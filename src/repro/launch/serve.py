"""Serving launcher.

Two modes, ONE workload spec and ONE metrics surface:

    --sim      cluster-scale discrete-event evaluation (the paper's SS7
               experiments): real control plane, modeled 16-worker
               cluster, any workload/policy.
    --real     real JAX AR-DiT execution on this host through the
               unified ``serve.session.StreamingSession``: the SAME
               ``ControlPlane.tick()`` decisions as --sim drive actual
               chunk generation (tiny model), over the same
               --workload/--rate/--seed StreamSpec generators, and the
               run prints the same one-line ``Summary.row()`` — so a
               workload can be compared sim-vs-real apples-to-apples.
               ``--lanes N`` serves through N device lanes (one batched
               executor + paged KV pool each) and re-enables re-homing
               and elastic SP: tick decisions become REAL cross-lane KV
               moves and Ulysses SP2 head splits; the run additionally
               reports decisions applied by the lane pool.

    PYTHONPATH=src python -m repro.launch.serve --sim \
        --workload steady --policy slackserve --streams 300
    PYTHONPATH=src python -m repro.launch.serve --real --streams 2
    PYTHONPATH=src python -m repro.launch.serve --real --batched \
        --workload burst --streams 6 --seed 0
    PYTHONPATH=src python -m repro.launch.serve --real --batched \
        --streams 4 --pool-streams 2        # oversubscribed page pool
    PYTHONPATH=src python -m repro.launch.serve --real --lanes 2 \
        --workload burst                    # multi-lane: migrations + SP
    PYTHONPATH=src python -m repro.launch.serve --real \
        --models ardit-self-forcing,ardit-causal-forcing \
        --streams 4                # heterogeneous co-serving, one pool
"""
from __future__ import annotations

import argparse
import os
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    mode = ap.add_mutually_exclusive_group(required=True)
    mode.add_argument("--sim", action="store_true")
    mode.add_argument("--real", action="store_true")
    ap.add_argument("--workload", default="steady")
    ap.add_argument("--policy", default="slackserve")
    ap.add_argument("--streams", type=int, default=None,
                    help="stream count (default: 300 for --sim, 6 for "
                         "--real — the live tiny model is the demo)")
    ap.add_argument("--lanes", type=int, default=1,
                    help="device lanes for --real (> 1 implies the "
                         "batched executor and re-enables re-homing + "
                         "elastic SP); with > 1 visible devices each "
                         "lane commits its pool to its own device and "
                         "cross-lane moves are real jax.device_put")
    ap.add_argument("--device-count", type=int, default=0,
                    help="force N host platform devices before JAX "
                         "initializes (XLA_FLAGS=--xla_force_host_"
                         "platform_device_count=N) so device-backed "
                         "lanes are testable on one CPU host")
    ap.add_argument("--workers-per-node", type=int, default=0,
                    help="lanes per node for --real --lanes "
                         "(0 -> all lanes in one node)")
    ap.add_argument("--budget-factor", type=float, default=0.0,
                    help="playout seconds per chunk as a multiple of "
                         "the measured top-fidelity latency (0 -> 4.0 "
                         "single-lane, 2.0 multi-lane: the tighter "
                         "budget keeps tail streams urgent so the "
                         "cross-lane mechanisms engage)")
    ap.add_argument("--rate", type=float, default=1.0)
    ap.add_argument("--model", default="causal-forcing")
    ap.add_argument("--models", default="",
                    help="comma-separated registry configs to CO-SERVE "
                         "on one lane pool (--real; implies --batched). "
                         "Streams are tagged round-robin; the first "
                         "model is the primary bundle and the report "
                         "adds per-model Summary rows")
    ap.add_argument("--chunks", type=int, default=4,
                    help="per-stream chunk cap for --real (the tiny "
                         "model; --sim uses the spec lengths as-is)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--batched", action="store_true",
                    help="credit-ordered micro-batch executor (--real)")
    ap.add_argument("--max-batch", type=int, default=0,
                    help="micro-batch cap per lane (0 -> 4, or 3 "
                         "multi-lane: a smaller batch keeps real "
                         "WAITING streams in loaded queues — the "
                         "congestion signal Algorithm 1 reads)")
    ap.add_argument("--arrival-scale", type=float, default=1.0,
                    help="multiply workload event times for --real "
                         "(< 1 compresses Poisson gaps / trace idles)")
    ap.add_argument("--pool-streams", type=int, default=0,
                    help="co-resident stream cap of the paged KV pool "
                         "(< --streams oversubscribes; 0 -> all fit)")
    ap.add_argument("--context-backend", choices=("gather", "paged"),
                    default="paged",
                    help="how sub-batches see cached KV: 'paged' serves "
                         "attention straight from the page pool through "
                         "block tables; 'gather' materializes the "
                         "contiguous context (reference path)")
    ap.add_argument("--front-door", action="store_true",
                    help="SLO-aware admission control in front of the "
                         "scheduler: predicted-TTFC admit/queue/reject "
                         "(+ autoscaling under --sim) and admission "
                         "stats in the report")
    ap.add_argument("--step-cache", action="store_true",
                    help="unlock the content-adaptive step cache as a "
                         "fifth fidelity axis: BMPR routes over the "
                         "270-point (cache-unlocked) frontier and "
                         "eligible denoise steps reuse cached residuals "
                         "(models/stepcache.py)")
    ap.add_argument("--calibrate", action="store_true",
                    help="after a --real run, fit the sim cost model to "
                         "the session's measured EMAs, replay the same "
                         "specs through the calibrated simulator, and "
                         "print the sim-vs-real QoE/TTFC agreement")
    args = ap.parse_args()

    if args.lanes > 1:
        args.batched = True          # lanes ride the batched executor
    if args.models:
        if not args.real:
            ap.error("--models only applies to --real (co-serving rides "
                     "the live batched executor)")
        args.batched = True          # co-serving rides the batched path
    if args.pool_streams and not (args.real and args.batched):
        ap.error("--pool-streams only applies to --real --batched")
    if any(a.startswith("--context-backend") for a in sys.argv[1:]) \
            and not (args.real and args.batched):
        ap.error("--context-backend only applies to --real --batched")
    if args.lanes > 1 and not args.real:
        ap.error("--lanes only applies to --real")
    if args.step_cache and not (args.real and args.batched):
        ap.error("--step-cache only applies to --real --batched (cache "
                 "hits ride the fused batched dispatch as no-op rows)")
    if args.calibrate and not args.real:
        ap.error("--calibrate only applies to --real (the sim IS the "
                 "model being calibrated)")
    if args.device_count:
        if not args.real:
            ap.error("--device-count only applies to --real")
        # must land in the environment BEFORE jax initializes its
        # backends (repro imports below pull jax in)
        flag = ("--xla_force_host_platform_device_count="
                f"{args.device_count}")
        os.environ["XLA_FLAGS"] = \
            (os.environ.get("XLA_FLAGS", "") + " " + flag).strip()

    from repro.sched_sim.metrics import summarize, transfer_stats
    from repro.sched_sim.workloads import WORKLOADS

    if args.real:
        from repro.serve.session import (SessionConfig, StreamingSession,
                                         cap_specs)

        # multi-lane demo defaults: enough streams that each lane's
        # queue exceeds the micro-batch (genuinely WAITING streams are
        # what Algorithm 1 calls congestion), odd so the lanes drain
        # unevenly and a relaxed receiver appears
        n_streams = (args.streams if args.streams is not None
                     else 15 if args.lanes > 1 else 6)
        # multi-lane default budget: tight enough that a lane still
        # holding work keeps URGENT streams even at solo speed (~2x the
        # measured top latency vs the single-lane demo's 4x), so when
        # the other lane drains first the sender/receiver pair of
        # Algorithm 1 actually materializes
        budget_factor = (args.budget_factor
                         or (2.0 if args.lanes > 1 else 4.0))
        raw = WORKLOADS[args.workload](n=n_streams, rate=args.rate,
                                       seed=args.seed)
        # multi-lane keeps the workload's length DIVERSITY (scaled into
        # the chunk budget) — lanes then drain unevenly, which is what
        # re-homing and elastic SP exist to absorb
        from repro.serve.session import scale_specs
        specs = (scale_specs(raw, args.chunks) if args.lanes > 1
                 else cap_specs(raw, args.chunks))
        model_list = [m.strip() for m in args.models.split(",")
                      if m.strip()]
        if model_list:
            import dataclasses as _dc
            specs = [_dc.replace(sp, model=model_list[i % len(model_list)])
                     for i, sp in enumerate(specs)]
        fd_cfg = None
        if args.front_door:
            from repro.sched_sim.frontdoor import FrontDoorConfig
            fd_cfg = FrontDoorConfig()        # autoscale forced off live
        session = StreamingSession(SessionConfig(
            executor="batched" if args.batched else "sequential",
            models=model_list or None,
            max_batch=args.max_batch
            or (3 if args.lanes > 1 else 4),
            lanes=args.lanes,
            workers_per_node=args.workers_per_node,
            budget_factor=budget_factor,
            # 0 -> everyone fits (per lane), like the legacy default
            pool_streams=args.pool_streams or n_streams + 1,
            context_backend=args.context_backend,
            arrival_scale=args.arrival_scale,
            front_door=fd_cfg,
            step_cache=args.step_cache,
            verbose=True))   # --seed varies the workload, not the model
        for spec in specs:
            session.submit(spec)
        res = session.run()
        s = summarize(res)
        label = (f"real-{args.lanes}-lane" if args.lanes > 1 else
                 "real-batched" if args.batched else "real-sequential")
        if model_list:
            label += f"-coserve[{','.join(model_list)}]"
        print(f"{label} on {args.workload}: {s.row()}")
        for line in s.model_rows():
            print(line)
        print(f"  rehomings={s.n_rehomings} elastic_sp={s.n_sp_events} "
              f"transfers={transfer_stats(res)}")
        if args.front_door:
            print(f"  admission: {res.admission}")
        if args.step_cache:
            print(f"  step_cache: {res.step_cache} "
                  f"avg_effective_window={s.avg_effective_window:.2f}")
        if args.calibrate:
            from repro.sched_sim.calibration import agreement, fit_session
            from repro.sched_sim.policies import make_policy
            from repro.sched_sim.simulator import Simulator
            report = fit_session(session)
            sim_cfg = report.sim_config(
                n_workers=args.lanes,
                workers_per_node=args.workers_per_node or args.lanes)
            sim_res = Simulator(sim_cfg, specs, make_policy(
                "slackserve", model=report.model,
                profile=report.profile())).run()
            agr = agreement(s, summarize(sim_res))
            print(f"  calibration: scale={report.scale:.3f} "
                  f"ratios={ {k: round(v, 3) for k, v in report.ratios.items()} }")
            print(f"  sim-vs-real: qoe {agr['qoe_sim']} vs "
                  f"{agr['qoe_real']} (|d|={agr['qoe_delta']}, "
                  f"tol {agr['qoe_tol']}), ttfc {agr['ttfc_sim_s']}s vs "
                  f"{agr['ttfc_real_s']}s (rel={agr['ttfc_rel_err']}, "
                  f"tol {agr['ttfc_rel_tol']}) -> "
                  f"{'OK' if agr['ok'] else 'DISAGREE'}")
        if args.lanes > 1:
            print(f"  applied: migrations={res.n_migrations_applied} "
                  f"sp_expands={res.n_sp_expands_applied} "
                  f"sp_releases={res.n_sp_releases_applied}")
            import jax
            lanes = session.lanes
            placement = [str(d) if d is not None else "default"
                         for d in getattr(lanes, "lane_devices", [])]
            print(f"  devices: {jax.local_device_count()} visible, "
                  f"lanes -> {placement}")
            ms = res.engine.measured_stats()
            if ms["count"]:
                print(f"  measured moves: n={ms['count']} "
                      f"bytes={ms['bytes']} "
                      f"bw={ms['bytes_per_s']:.3g} B/s "
                      f"(model {ms['bw_intra_model']:.3g} -> "
                      f"calibrated {ms['bw_intra_calibrated']:.3g})")
        return

    from repro.sched_sim.policies import SDV2Policy, make_policy
    from repro.sched_sim.simulator import SimConfig, Simulator

    specs = WORKLOADS[args.workload](
        n=args.streams if args.streams is not None else 300,
        rate=args.rate, seed=args.seed)
    policy = make_policy(args.policy, model=args.model)
    sim_cfg = (SDV2Policy.sim_config() if args.policy == "sdv2"
               else SimConfig(model=args.model))
    if args.front_door:
        import dataclasses as _dc

        from repro.sched_sim.frontdoor import FrontDoorConfig
        sim_cfg = _dc.replace(sim_cfg, front_door=FrontDoorConfig())
    res = Simulator(sim_cfg, specs, policy).run()
    s = summarize(res)
    print(f"{args.policy} on {args.workload}: {s.row()}")
    for line in s.model_rows():          # mixed_models workload
        print(line)
    print(f"  rehomings={s.n_rehomings} elastic_sp={s.n_sp_events} "
          f"transfers={transfer_stats(res)}")
    if args.front_door:
        print(f"  admission: {res.admission} "
              f"(final workers: {res.n_workers_final})")


if __name__ == "__main__":
    main()
