"""Production mesh construction.

Defined as FUNCTIONS (not module constants) so importing this module
never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before its
first jax import, and everything else must see the real device count.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_devices: int | None = None):
    """Tiny mesh over whatever devices exist (CPU tests)."""
    n = n_devices or len(jax.devices())
    model = 1
    for m in (4, 2, 1):
        if n % m == 0:
            model = m
            break
    return jax.make_mesh((n // model, model), ("data", "model"))
