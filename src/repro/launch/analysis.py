"""Compiled-artifact analysis: memory, FLOPs/bytes, collective traffic,
and the three-term roofline (EXPERIMENTS.md SSRoofline).

    compute    = HLO_FLOPs / (chips x peak FLOP/s)
    memory     = HLO_bytes / (chips x HBM bandwidth)
    collective = collective_bytes / (chips x ICI link bandwidth)

Hardware constants: TPU v5e-class — 197 TFLOP/s bf16 per chip, 819 GB/s
HBM, ~50 GB/s/link ICI.  ``cost_analysis`` provides FLOPs/bytes;
collective bytes are summed from the optimized HLO text (result-shape
bytes of all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute ops).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional, Tuple

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f16": 2, "bf16": 2, "f32": 4, "f64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
}

_COLL_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(sig: str) -> int:
    """Bytes of an HLO result signature (handles tuples)."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(sig):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum result-shape bytes per collective op kind."""
    out: Dict[str, int] = {k: 0 for k in _COLL_OPS}
    for line in hlo_text.splitlines():
        s = line.lstrip()
        # "%name = TYPE[...] opcode(" or "ROOT %x = (tuple) opcode("
        m = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.+?)\s+"
                     r"([a-z\-]+)(?:-start|-done)?\(", s)
        if not m:
            continue
        sig, op = m.group(1), m.group(2)
        if op in _COLL_OPS:
            if op.endswith("-start") or "-done" in s.split("(")[0]:
                pass
            out[op] += _shape_bytes(sig)
    return out


@dataclasses.dataclass(frozen=True)
class Roofline:
    flops: float                  # total HLO FLOPs (all devices)
    hbm_bytes: float              # total bytes accessed
    coll_bytes: float             # total collective bytes
    coll_by_op: Dict[str, int]
    n_chips: int
    per_device_bytes: Optional[float]   # argument+output+temp per device

    @property
    def t_compute(self) -> float:
        return self.flops / (self.n_chips * PEAK_FLOPS)

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / (self.n_chips * HBM_BW)

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / (self.n_chips * ICI_BW)

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def step_time(self) -> float:
        """Roofline-optimistic step time: max of the three terms
        (perfect overlap assumption)."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    def row(self) -> Dict[str, float]:
        return {
            "flops": self.flops, "hbm_bytes": self.hbm_bytes,
            "coll_bytes": self.coll_bytes,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "dominant": self.dominant,
            "per_device_bytes": self.per_device_bytes,
        }


def analyze(lowered, compiled, n_chips: int) -> Roofline:
    """Roofline terms from the compiled per-device SPMD module.

    Uses the while-loop-aware HLO analyzer (repro.launch.hlo_cost) —
    XLA's own cost_analysis counts scan bodies once and is useless for
    scan-over-layers programs.  All analyzer numbers are PER-DEVICE, so
    totals are x n_chips.
    """
    from repro.launch import hlo_cost
    text = compiled.as_text()
    cost = hlo_cost.analyze_text(text)
    per_dev = None
    try:
        ma = compiled.memory_analysis()
        per_dev = float(ma.argument_size_in_bytes
                        + ma.output_size_in_bytes
                        + ma.temp_size_in_bytes)
    except Exception:
        pass
    return Roofline(flops=cost.flops * n_chips,
                    hbm_bytes=cost.hbm_bytes * n_chips,
                    coll_bytes=cost.coll_bytes * n_chips,
                    coll_by_op={k: int(v * n_chips)
                                for k, v in cost.coll_by_op.items()},
                    n_chips=n_chips,
                    per_device_bytes=per_dev)


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS: 6*N*D (dense) / 6*N_active*D (MoE) for train;
    2*N*D for prefill; 2*N per token x batch for decode."""
    from repro.configs.base import ModelConfig
    n_active = active_params(cfg)
    tokens = shape.global_batch * shape.seq_len
    if shape.kind == "train":
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch      # one new token each


def active_params(cfg) -> float:
    """Analytic active-parameter count (MoE: top_k experts only)."""
    d, f, L, v = cfg.d_model, cfg.d_ff, cfg.n_layers, cfg.vocab_size
    if cfg.family == "ssm":
        di = cfg.ssm_expand * d
        n = cfg.ssm_state
        per = d * (2 * di + 2 * n + di // cfg.ssm_head_dim) + di * d
        return L * per + v * d
    hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    attn = d * (hq * dh) + 2 * d * (hkv * dh) + (hq * dh) * d
    if cfg.family == "hybrid":
        import math
        from repro.models.hybrid import sublayer_kinds
        kinds = sublayer_kinds(cfg)
        di = cfg.ssm_expand * d
        n = cfg.ssm_state
        mamba = d * (2 * di + 2 * n + di // cfg.ssm_head_dim) + di * d
        total = 0.0
        for mixer, ffn in kinds:
            total += attn if mixer == "attn" else mamba
            if ffn == "moe":
                total += cfg.top_k * 3 * d * cfg.moe_d_ff
            else:
                total += 3 * d * f
        return total * (L / len(kinds)) + 2 * v * d
    if cfg.n_experts:
        ffn = cfg.top_k * 3 * d * cfg.moe_d_ff + d * cfg.n_experts
    elif cfg.act == "swiglu":
        ffn = 3 * d * f
    else:
        ffn = 2 * d * f
    n_layers = L
    if cfg.family == "encdec":
        n_layers = cfg.n_enc_layers + cfg.n_dec_layers
        attn = attn * 1.5            # decoder adds cross-attention
    emb = v * d * (1 if cfg.tie_embeddings else 2)
    return n_layers * (attn + ffn) + emb
