"""Training launcher: real steps on the local device(s).

    PYTHONPATH=src python -m repro.launch.train --arch minicpm-2b \
        --steps 50 --batch 4 --seq 128 [--reduced] [--ckpt-dir DIR]

Runs the full substrate end-to-end: synthetic data pipeline, AdamW +
schedule, microbatching, async checkpoint/restart (resume is automatic
when ``--ckpt-dir`` holds a checkpoint).  ``--reduced`` (default on CPU)
trains the tiny same-family config; full configs are exercised by the
dry-run.  Restart mid-run is the fault-tolerance path: kill the process
and relaunch with the same arguments — it resumes from the latest step
with bitwise-identical data.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax

from repro.configs.base import ShapeConfig, get_config
from repro.data import pipeline as dp
from repro.models import registry
from repro.train import checkpoint as ckpt
from repro.train import loop as train_loop
from repro.train import optimizer as opt


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minicpm-2b")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--full", action="store_true",
                    help="use the full config (needs real accelerators)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    shape = ShapeConfig("cli", "train", args.seq, args.batch)
    api = registry.get_api(cfg)

    ocfg = opt.OptConfig(
        lr=args.lr, total_steps=max(args.steps, 10),
        warmup_steps=max(2, args.steps // 10),
        schedule="wsd" if args.arch.startswith("minicpm") else "cosine")
    step_fn = jax.jit(train_loop.make_train_step(
        cfg, ocfg, microbatches=args.microbatches))

    start = 0
    params = api.init(cfg, jax.random.PRNGKey(0))
    state = train_loop.TrainState(params, opt.init_opt_state(params))
    if args.ckpt_dir:
        latest = ckpt.latest_step(args.ckpt_dir)
        if latest is not None:
            state = ckpt.restore(args.ckpt_dir, latest, state)
            start = latest
            print(f"resumed from step {start}")

    pending = None
    t0 = time.time()
    for step in range(start, args.steps):
        batch = dp.global_batch(cfg, shape, step)
        state, metrics = step_fn(state, batch)
        print(f"step {step:5d} loss={float(metrics['loss']):.4f} "
              f"gnorm={float(metrics['grad_norm']):.3f} "
              f"lr={float(metrics['lr']):.2e} "
              f"({(time.time()-t0)/(step-start+1):.2f}s/step)")
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            if pending is not None:
                pending.join()
            pending = ckpt.save(args.ckpt_dir, step + 1, state,
                                blocking=False)
    if pending is not None:
        pending.join()
    if args.ckpt_dir:
        ckpt.save(args.ckpt_dir, args.steps, state)
        print(f"final checkpoint at step {args.steps}")


if __name__ == "__main__":
    main()
