"""While-loop-aware cost analysis over optimized HLO text.

``compiled.cost_analysis()`` counts a while-loop body ONCE, which makes
it useless for scan-over-layers programs (the whole transformer lives in
one loop body).  This analyzer parses the optimized HLO module, builds
the computation call graph, extracts counted-loop trip counts from the
canonical ``compare(iter, constant)`` condition, and multiplies each
computation's cost by its total multiplicity:

    flops       2*prod(batch)*M*N*K per dot (incl. dots inside fusions)
    hbm bytes   operand+result bytes of top-level ops in unfused
                computations (post-fusion HLO: fusion boundaries ARE the
                HBM traffic boundaries)
    collective  result bytes of all-gather / all-reduce / reduce-scatter
                / all-to-all / collective-permute ops

All numbers are PER-DEVICE (the compiled module is the per-device SPMD
program).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f16": 2, "bf16": 2,
    "f32": 4, "f64": 8, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_COLL_OPS = {"all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute", "all-gather-start", "all-reduce-start",
             "collective-permute-start"}

_SHAPE_TOKEN = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")


def _shape_list(sig: str) -> List[Tuple[str, Tuple[int, ...]]]:
    out = []
    for dt, dims in _SHAPE_TOKEN.findall(sig):
        if dt not in _DTYPE_BYTES:
            continue
        shape = tuple(int(d) for d in dims.split(",") if d)
        out.append((dt, shape))
    return out


def _sig_bytes(sig: str) -> int:
    total = 0
    for dt, shape in _shape_list(sig):
        n = 1
        for d in shape:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class OpLine:
    name: str
    result_sig: str
    opcode: str
    operands: List[str]
    attrs: str
    raw: str


@dataclasses.dataclass
class Computation:
    name: str
    ops: List[OpLine]
    is_fusion_body: bool


def _balanced(s: str, start: int) -> int:
    """Index just past the balanced paren group opening at ``start``."""
    depth = 0
    for i in range(start, len(s)):
        if s[i] == "(":
            depth += 1
        elif s[i] == ")":
            depth -= 1
            if depth == 0:
                return i + 1
    return len(s)


def _split_top(s: str) -> List[str]:
    """Split on top-level commas (ignoring (), [], {} nesting)."""
    out, depth, cur = [], 0, []
    for ch in s:
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        if ch == "," and depth == 0:
            out.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    if cur:
        out.append("".join(cur))
    return [x.strip() for x in out if x.strip()]


_OPCODE_RE = re.compile(r"([\w\-]+)\(")


def _parse_op(line: str) -> Optional[OpLine]:
    s = line.strip()
    if s.startswith("ROOT "):
        s = s[5:]
    if not s.startswith("%"):
        return None
    eq = s.find(" = ")
    if eq < 0:
        return None
    name = s[:eq].strip()
    rest = s[eq + 3:]
    if rest.startswith("("):                    # tuple result type
        end = _balanced(rest, 0)
        sig, rest2 = rest[:end], rest[end:].lstrip()
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        sig, rest2 = rest[:sp], rest[sp + 1:].lstrip()
    m = _OPCODE_RE.match(rest2)
    if not m:
        return None
    opcode = m.group(1)
    a0 = rest2.find("(")
    a1 = _balanced(rest2, a0)
    operands = [a.split(" ")[-1] for a in _split_top(rest2[a0 + 1:a1 - 1])]
    return OpLine(name, sig, opcode, operands, s, s)


def parse_module(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        stripped = line.strip()
        header = re.match(
            r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$", stripped)
        if header:
            name = "%" + header.group(2)
            cur = Computation(name, [], is_fusion_body=False)
            comps[name] = cur
            if header.group(1):
                comps["__entry__"] = cur
            continue
        if cur is None:
            continue
        if stripped == "}":
            cur = None
            continue
        op = _parse_op(line)
        if op is not None:
            cur.ops.append(op)
    return comps


def _dot_flops(op: OpLine, shapes: Dict[str, str]) -> float:
    """2 * prod(batch) * M * N * K from the dot's dnums + shapes."""
    lhs_sig = shapes.get(op.operands[0], "") if op.operands else ""
    out_shapes = _shape_list(op.result_sig)
    lhs_shapes = _shape_list(lhs_sig)
    if not out_shapes or not lhs_shapes:
        return 0.0
    out_elems = 1
    for d in out_shapes[0][1]:
        out_elems *= d
    lhs = lhs_shapes[0][1]
    cdims = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.raw)
    k = 1
    if cdims:
        for d in cdims.group(1).split(","):
            if d:
                k *= lhs[int(d)]
    return 2.0 * out_elems * k


def _trip_count(while_raw: str,
                cond: Optional[Computation]) -> int:
    """Trip count: XLA's known_trip_count backend_config, else the
    canonical ``compare(iter, constant)`` condition constant."""
    m = re.search(r'"known_trip_count":\{"n":"(\d+)"', while_raw)
    if m:
        return int(m.group(1))
    const = None
    if cond is not None:
        for op in cond.ops:
            if op.opcode == "constant":
                mm = re.search(r"constant\((-?\d+)\)", op.raw)
                if mm:
                    const = int(mm.group(1))
    if const is not None and const > 0:
        return const
    return 1


@dataclasses.dataclass
class HloCost:
    flops: float
    hbm_bytes: float
    coll_bytes: float
    coll_by_op: Dict[str, float]


_SKIP_TRAFFIC = {"parameter", "constant", "get-tuple-element", "tuple",
                 "bitcast", "while", "conditional", "call",
                 "after-all", "partition-id", "replica-id", "iota"}


def _is_pure_convert(callee: "Computation") -> bool:
    """A fusion that only converts dtypes (bf16->f32 staging for dots).
    The CPU backend has no native bf16 matmul and materializes converted
    weight copies; the TPU MXU consumes bf16 directly, so these fusions
    are zero HBM traffic on the target."""
    return all(o.opcode in ("parameter", "convert", "bitcast", "copy")
               for o in callee.ops)


def _fusion_operand_sigs(callee: "Computation", op: OpLine,
                         operand_sigs: List[Optional[str]]
                         ) -> List[Optional[str]]:
    """Per-operand effective read size for a fusion: if the fused body
    only consumes parameter i through slice/dynamic-slice ops, the real
    read is the slice result(s), not the whole operand."""
    params: Dict[int, str] = {}
    for o in callee.ops:
        if o.opcode == "parameter":
            mm = re.search(r"parameter\((\d+)\)", o.raw)
            if mm:
                params[int(mm.group(1))] = o.name
    out = list(operand_sigs)
    for idx, sig in enumerate(operand_sigs):
        pname = params.get(idx)
        if pname is None or sig is None:
            continue
        consumers = [o for o in callee.ops if pname in o.operands]
        if consumers and all(o.opcode in ("slice", "dynamic-slice",
                                          "gather")
                             for o in consumers):
            out[idx] = " ".join(o.result_sig for o in consumers)
    return out


def top_ops(text: str, n: int = 12,
            kind: str = "collective") -> List[Tuple[float, str, str]]:
    """Largest traffic/collective contributors (bytes x multiplicity) —
    the profiling primitive of the SSPerf hypothesis loop."""
    comps = parse_module(text)
    entry = comps.get("__entry__") or max(comps.values(),
                                          key=lambda c: len(c.ops))
    mult, comp_trip, top_level = _propagate(comps, entry)
    rows: List[Tuple[float, str, str]] = []
    for cname, comp in comps.items():
        if cname == "__entry__":
            continue
        m = mult.get(cname, 0.0)
        if m <= 0:
            continue
        trip = comp_trip.get(cname, 1)
        shapes = {op.name: op.result_sig for op in comp.ops}
        for op in comp.ops:
            base = op.opcode.replace("-start", "")
            if kind == "collective":
                if base in ("all-gather", "all-reduce", "reduce-scatter",
                            "all-to-all", "collective-permute"):
                    rows.append((m * _sig_bytes(op.result_sig), base,
                                 op.raw[:150]))
            elif cname in top_level and op.opcode not in _SKIP_TRAFFIC:
                b = _sig_bytes(op.result_sig)
                rows.append((m * b, op.opcode, op.raw[:150]))
    rows.sort(reverse=True)
    return rows[:n]


def _propagate(comps, entry):
    mult: Dict[str, float] = {entry.name: 1.0}
    order = [entry.name]
    seen = {entry.name}
    comp_trip: Dict[str, int] = {}
    top_level = {entry.name}
    i = 0
    while i < len(order):
        cname = order[i]
        i += 1
        comp = comps.get(cname)
        if comp is None:
            continue
        for op in comp.ops:
            callees = []
            if op.opcode == "while":
                body = re.search(r"body=%?([\w\.\-]+)", op.raw)
                cond = re.search(r"condition=%?([\w\.\-]+)", op.raw)
                trip = _trip_count(op.raw, comps.get(
                    "%" + cond.group(1)) if cond else None)
                if body:
                    callees.append(("%" + body.group(1), float(trip)))
                    top_level.add("%" + body.group(1))
                    comp_trip["%" + body.group(1)] = trip
                if cond:
                    callees.append(("%" + cond.group(1), float(trip + 1)))
                    top_level.add("%" + cond.group(1))
            else:
                for attr in ("calls", "to_apply"):
                    mm = re.search(attr + r"=%?([\w\.\-]+)", op.raw)
                    if mm:
                        callees.append(("%" + mm.group(1), 1.0))
            for (callee, f) in callees:
                if callee not in comps:
                    continue
                mult[callee] = mult.get(callee, 0.0) + mult[cname] * f
                if callee not in seen:
                    seen.add(callee)
                    order.append(callee)
    return mult, comp_trip, top_level


def analyze_text(text: str) -> HloCost:
    comps = parse_module(text)
    entry = comps.get("__entry__")
    if entry is None:
        # fall back: biggest computation
        entry = max(comps.values(), key=lambda c: len(c.ops))

    # multiplicity propagation over the call graph
    mult: Dict[str, float] = {c: 0.0 for c in comps}
    mult[entry.name] = 1.0
    order = [entry.name]
    seen = {entry.name}
    i = 0
    while i < len(order):
        cname = order[i]
        i += 1
        comp = comps.get(cname)
        if comp is None:
            continue
        m_here = mult[cname]
        for op in comp.ops:
            callees = []
            factor = 1.0
            if op.opcode == "while":
                body = re.search(r"body=%?([\w\.\-]+)", op.raw)
                cond = re.search(r"condition=%?([\w\.\-]+)", op.raw)
                cond_comp = comps.get("%" + cond.group(1)) if cond else None
                trip = _trip_count(op.raw, cond_comp)
                if body:
                    callees.append(("%" + body.group(1), float(trip)))
                if cond:
                    callees.append(("%" + cond.group(1), float(trip + 1)))
            else:
                for attr in ("calls", "to_apply"):
                    mm = re.search(attr + r"=%?([\w\.\-]+)", op.raw)
                    if mm:
                        callees.append(("%" + mm.group(1), 1.0))
                mm = re.search(r"branch_computations=\{([^}]*)\}", op.raw)
                if mm:
                    for b in mm.group(1).split(","):
                        callees.append((b.strip().lstrip("%").join(
                            ["%", ""]), 1.0))
            for (callee, f) in callees:
                if callee not in comps:
                    continue
                mult[callee] = mult.get(callee, 0.0) + m_here * f
                if callee not in seen:
                    seen.add(callee)
                    order.append(callee)

    # computations reached ONLY through calls=/to_apply are fused bodies:
    # their internals are not HBM traffic.  Top-level = entry + while
    # bodies/conditions + conditional branches.  while bodies remember
    # their trip count for the scan-carry traffic rule below.
    top_level = {entry.name}
    comp_trip: Dict[str, int] = {}
    for cname, comp in comps.items():
        for op in comp.ops:
            if op.opcode == "while":
                cond = re.search(r"condition=%?([\w\.\-]+)", op.raw)
                trip = _trip_count(op.raw,
                                   comps.get("%" + cond.group(1))
                                   if cond else None)
                for attr in ("body", "condition"):
                    mm = re.search(attr + r"=%?([\w\.\-]+)", op.raw)
                    if mm:
                        top_level.add("%" + mm.group(1))
                        comp_trip["%" + mm.group(1)] = trip
            mm = re.search(r"branch_computations=\{([^}]*)\}", op.raw)
            if mm:
                for b in mm.group(1).split(","):
                    top_level.add("%" + b.strip().lstrip("%"))

    def _traffic_bytes(sig: str, trip: int) -> float:
        """HBM bytes for one access of a tensor inside a T-trip loop
        body, with two target-hardware adjustments:

        * scan-carry stacks (leading dim == T) are touched one slice
          per iteration, not wholesale (in-place dynamic slice/update);
        * rank-5 f32/pred tensors are the attention-score / SSD-segment
          internals of this substrate's einsum conventions
          ([B,Hkv,G,q,k] scores+masks, [B,nc,Q,Q,H] SSD L-matrices) —
          on the TPU target they live in the Pallas kernels' VMEM
          scratch and never reach HBM (flops still counted).
        """
        total = 0.0
        for dt, shape in _shape_list(sig):
            if len(shape) == 5 and dt in ("f32", "pred"):
                continue
            n = 1
            for d in shape:
                n *= d
            b = n * _DTYPE_BYTES[dt]
            if trip > 1 and shape and shape[0] == trip:
                b /= trip
            total += b
        return total

    flops = 0.0
    hbm = 0.0
    coll = 0.0
    coll_by: Dict[str, float] = {}
    for cname, comp in comps.items():
        if cname == "__entry__":
            continue
        m = mult.get(cname, 0.0)
        if m <= 0.0:
            continue
        trip = comp_trip.get(cname, 1)
        shapes = {op.name: op.result_sig for op in comp.ops}
        for op in comp.ops:
            if op.opcode in ("dot", "convolution"):
                flops += m * _dot_flops(op, shapes)
            base = op.opcode.replace("-start", "")
            if base in ("all-gather", "all-reduce", "reduce-scatter",
                        "all-to-all", "collective-permute"):
                b = _sig_bytes(op.result_sig)
                coll += m * b
                coll_by[base] = coll_by.get(base, 0.0) + m * b
            if (cname in top_level
                    and op.opcode not in _SKIP_TRAFFIC
                    and not op.opcode.endswith("-done")):
                if op.opcode in ("slice", "dynamic-slice", "gather"):
                    # a slice reads only the sliced region (== result),
                    # not its whole source operand
                    hbm += m * 2.0 * _traffic_bytes(op.result_sig, trip)
                    continue
                operand_sigs = [shapes.get(o) for o in op.operands]
                # fusion refinement: pure dtype-convert fusions are
                # zero-traffic on the TPU target; an operand the fused
                # body only SLICES is read at slice granularity
                if op.opcode == "fusion":
                    mm = re.search(r"calls=%?([\w\.\-]+)", op.raw)
                    callee = comps.get("%" + mm.group(1)) if mm else None
                    if callee is not None:
                        if _is_pure_convert(callee):
                            continue
                        operand_sigs = _fusion_operand_sigs(
                            callee, op, operand_sigs)
                operand_sigs = [s for s in operand_sigs if s]
                # in-place aliasing: an operand with the result's exact
                # signature buffer-shares it (DUS carries, elementwise
                # donation) — count the operand reads, skip the result
                aliased = op.result_sig in operand_sigs
                op_bytes = 0.0 if aliased else _traffic_bytes(
                    op.result_sig, trip)
                for sig in operand_sigs:
                    op_bytes += _traffic_bytes(sig, trip)
                hbm += m * op_bytes
    return HloCost(flops=flops, hbm_bytes=hbm, coll_bytes=coll,
                   coll_by_op=coll_by)
